# Local mirror of .github/workflows/ci.yml.  `make ci` is the tier-1 gate;
# ruff runs only when installed (the CI image always installs it).
PY ?= python

.PHONY: ci test lint bench-smoke bench-paged bench-prefill serve-sim serve-chaos serve-recover serve-prefix serve-validate

ci: lint test

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# Smoke-size serving benchmarks (interpret-mode kernels on CPU); emit the
# machine-readable BENCH_PR2.json / BENCH_PR3.json / BENCH_PR4.json that CI
# uploads as artifacts.  BENCH_PR3 additionally asserts continuous batching
# sustains >= static-batch decode throughput on a heavy-tailed Poisson
# workload; BENCH_PR4 asserts the fused paged-attention path beats the
# gather-dense path at >= 50% pool occupancy.
bench-smoke:
	PYTHONPATH=src $(PY) benchmarks/serve_decode.py --smoke --out BENCH_PR2.json
	PYTHONPATH=src $(PY) benchmarks/serve_traffic.py --smoke --out BENCH_PR3.json
	PYTHONPATH=src $(PY) benchmarks/paged_attention.py --smoke --check --out BENCH_PR4.json
	PYTHONPATH=src $(PY) benchmarks/prefill.py --smoke --check --out BENCH_PR5.json
	PYTHONPATH=src $(PY) benchmarks/serve_traffic.py --overload --smoke --out BENCH_PR9.json
	PYTHONPATH=src $(PY) benchmarks/serve_traffic.py --prefix-share --smoke --out BENCH_PR10.json

# Paged-attention gate: measures fresh (never trusts a checked-in JSON)
# and asserts the fused path's decode tok/s >= the gather-dense path at
# >= 50% pool occupancy (interpret mode on CPU) plus pool-size-independent
# fused bytes/throughput.  CI re-asserts the artifact bench-smoke just
# produced via --check-file instead of re-running the scan.
bench-paged:
	PYTHONPATH=src $(PY) benchmarks/paged_attention.py --smoke --check --no-serve --out /tmp/BENCH_PR4_gate.json

# Chunked-prefill gate: measures fresh and asserts chunked prefill keeps
# decode flowing during long-prompt admission (strictly beating blocking's
# during-prefill decode tok/s), improves interactive TTFT p50 under the
# co-arrival burst mix, sustains steady-mix aggregate throughput, and
# leaves zero per-admission dispatches/host syncs.  CI re-asserts the
# artifact bench-smoke just produced via --check-file.
bench-prefill:
	PYTHONPATH=src $(PY) benchmarks/prefill.py --smoke --check --out /tmp/BENCH_PR5_gate.json

# 50-request continuous-batching traffic sim (scheduler + paged KV pool
# smoke: completion, O(1) dispatch/segment, and no-leak invariants).
# Emits the run's metrics registry + Chrome trace (perfetto-openable) as
# artifacts; `make serve-validate` smoke-checks them.
serve-sim:
	PYTHONPATH=src $(PY) benchmarks/serve_traffic.py --requests 50 --sim-only \
		--metrics-out serve_sim_metrics.prom --trace-out serve_sim_trace.json

# 50-request seeded chaos smoke: hidden-block pool pressure, forced
# preemption storms, NaN logits, and surprise cancels through the REAL
# scheduler/allocator paths.  Asserts surviving requests are bit-identical
# to the fault-free run, interrupted ones are clean prefixes, the
# allocator drains exactly full, and the exported trace shows the injected
# faults / preemptions / defrags as named events.
serve-chaos:
	PYTHONPATH=src $(PY) benchmarks/serve_traffic.py --chaos --smoke \
		--metrics-out serve_chaos_metrics.prom --trace-out serve_chaos_trace.json

# Crash-point recovery chaos: a page-out run with periodic snapshots is
# killed mid-flight by a scripted CrashPoint; a FRESH engine restores the
# last snapshot and resumes.  Asserts every request completes
# bit-identically to an uninterrupted run, and exports the crash + resume
# traces (spill / snapshot / recover spans) plus the snapshot directory.
serve-recover:
	PYTHONPATH=src $(PY) benchmarks/serve_traffic.py --recover --smoke \
		--snapshot-dir serve_recover_snaps \
		--metrics-out serve_recover_metrics.prom \
		--trace-out serve_recover_trace.json

# Shared-prefix traffic smoke: 80% shared-system-prefix workload through
# the prefix-cached engine vs an uncached engine at equal pool (strict
# TTFT p50 win + concurrency >= asserted, BENCH_PR10.json), then a
# scripted preempt + cache-flush storm on the warm cached engine — every
# stream must stay bit-identical to the uncached reference.  Exports the
# storm trace (prefix_hit / cow_copy / fault:flush events) + metrics.
serve-prefix:
	PYTHONPATH=src $(PY) benchmarks/serve_traffic.py --prefix-share --smoke \
		--out BENCH_PR10.json \
		--metrics-out serve_prefix_metrics.prom \
		--trace-out serve_prefix_trace.json
	PYTHONPATH=src $(PY) -m repro.serve.telemetry validate \
		bench_out/serve_prefix_trace.json \
		--require-names segment,retire,prefix_hit,cow_copy,preempt \
		--require-prefix fault:

# Validate the telemetry artifacts serve-sim / serve-chaos / serve-recover
# just wrote under bench_out/: traces parse as Chrome trace-event JSON
# with the required phases (X spans, i instants, C counters, M metadata)
# and serve events present.
serve-validate:
	PYTHONPATH=src $(PY) -m repro.serve.telemetry validate \
		bench_out/serve_sim_trace.json --require-names segment,retire
	PYTHONPATH=src $(PY) -m repro.serve.telemetry validate \
		bench_out/serve_chaos_trace.json \
		--require-names segment,preempt,retire --require-prefix fault:
	PYTHONPATH=src $(PY) -m repro.serve.telemetry validate \
		bench_out/serve_recover_trace.json \
		--require-names segment,spill,snapshot,preempt --require-prefix fault:
	PYTHONPATH=src $(PY) -m repro.serve.telemetry validate \
		bench_out/serve_recover_trace_resume.json \
		--require-names recover,segment,retire

lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples; \
	else \
		echo "ruff not installed; skipping lint (CI runs it)"; \
	fi
