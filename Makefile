# Local mirror of .github/workflows/ci.yml.  `make ci` is the tier-1 gate;
# ruff runs only when installed (the CI image always installs it).
PY ?= python

.PHONY: ci test lint bench-smoke

ci: lint test

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# Smoke-size serving benchmark (interpret-mode kernels on CPU); emits the
# machine-readable BENCH_PR2.json that CI uploads as an artifact.
bench-smoke:
	PYTHONPATH=src $(PY) benchmarks/serve_decode.py --smoke --out BENCH_PR2.json

lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples; \
	else \
		echo "ruff not installed; skipping lint (CI runs it)"; \
	fi
